"""Differential fuzz suite: native Kokkos C++ vs the compiled jax callable.

The strongest claim the repro can make about ``lapis-translate`` is not
that its text matches a golden but that its *numbers* match the
compiled callable.  This suite closes that loop for every registered
backend: randomized graphs (elementwise map, fused chain, gemm,
CSR- and ELL-layout spmv, paged block copy) are compiled twice — once
through the jax path, once to C++ built against the executable Kokkos
stub (or real Kokkos when ``$KOKKOS_ROOT`` is set) and ctypes-loaded
through the C-ABI harness — and the same randomized inputs must agree to
f32 tolerance.  Shapes are deliberately odd (primes, non-multiples of
every declared tile width) so row-block remainder handling is always
exercised.

The golden units are also *run* here (not just text-diffed): each
``tests/golden/translate/*.cpp`` must build as an executable and print
its checksum line, so a golden that stops being a program fails the
suite even while its text still matches.

Skips cleanly when no C++ compiler is present.
"""
import pathlib
import subprocess
import zlib

import jax
import numpy as np
import pytest

from repro.core import native, ops, pipeline
from repro.core.options import CompileOptions

pytestmark = pytest.mark.skipif(native.compiler() is None,
                                reason="no C++ compiler present")

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden" / "translate"
TOL = 1e-4


def _backends():
    # frozen at collection time: tests elsewhere register throwaway
    # backends (e.g. test_backend's dummy-test) that must not leak into
    # the case matrix or the golden-coverage contract
    from repro.core import backend as backend_mod
    return backend_mod.available_backends()


_BACKENDS = _backends()


# ---------------------------------------------------------------------------
# randomized graph builders — odd shapes, seeded per graph name
# ---------------------------------------------------------------------------

def _rng(name):
    # stable per-case seed (crc32, not hash(): no per-process salt) —
    # deterministic failures, distinct draws per graph
    return np.random.default_rng(zlib.crc32(name.encode()))


def _map_graph(rng):
    """Pure elementwise chain — the linalg.map path, one fused region."""
    b = rng.standard_normal((5, 13), dtype=np.float32)

    def fn(x):
        return ops.relu(ops.mul(ops.add(x, ops.constant(b)),
                                ops.constant(b)))
    specs = (jax.ShapeDtypeStruct((5, 13), "float32"),)
    args = (rng.standard_normal((5, 13), dtype=np.float32),)
    return fn, specs, args


def _fused_graph(rng):
    """matmul -> fused bias+relu -> softmax: fused-region replay plus a
    reduce nest, with a prime row count so no tile divides evenly."""
    w = rng.standard_normal((17, 11), dtype=np.float32)
    b = rng.standard_normal((7, 11), dtype=np.float32)

    def fn(x):
        return ops.softmax(ops.relu(ops.add(ops.matmul(x, ops.constant(w)),
                                            ops.constant(b))))
    specs = (jax.ShapeDtypeStruct((7, 17), "float32"),)
    args = (rng.standard_normal((7, 17), dtype=np.float32),)
    return fn, specs, args


def _gemm_graph(rng):
    w = rng.standard_normal((19, 23), dtype=np.float32)

    def fn(x):
        return ops.matmul(x, ops.constant(w))
    specs = (jax.ShapeDtypeStruct((13, 19), "float32"),)
    args = (rng.standard_normal((13, 19), dtype=np.float32),)
    return fn, specs, args


def _random_csr(rng, n_rows, n_cols, nnz_mean):
    lens = np.maximum(rng.poisson(nnz_mean, n_rows), 1).astype(np.int32)
    indptr = np.zeros(n_rows + 1, np.int32)
    np.cumsum(lens, out=indptr[1:])
    nnz = int(indptr[-1])
    return (indptr, rng.integers(0, n_cols, nnz).astype(np.int32),
            rng.standard_normal(nnz).astype(np.float32), int(lens.max()))


def _spmv_graph(rng, ell):
    """y = relu(A @ x): with a static ELL width the capable backends pin
    the CSR->ELL conversion + ELL row loop; without one, every backend
    keeps the CSR row loop — both layouts hit the oracle."""
    n = 29
    indptr, indices, values, max_row = _random_csr(rng, n, n, 3.0)
    max_nnz_row = max_row if ell else None

    def fn(ip, ind, val, x):
        return ops.relu(ops.spmv_csr(ip, ind, val, x, n_rows=n,
                                     nnz_mean=3.0,
                                     max_nnz_row=max_nnz_row))
    specs = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype)
                  for a in (indptr, indices, values))
    specs += (jax.ShapeDtypeStruct((n,), "float32"),)
    args = (indptr, indices, values,
            rng.standard_normal(n).astype(np.float32))
    return fn, specs, args


def _paged_copy_graph(rng):
    """Block-granular arena copy (the serving engine's CoW fork path)."""
    n_blocks, heads, bs, hd = 7, 3, 4, 5

    def fn(pool, src_ids, dst_ids):
        return ops.page_copy(pool, pool, src_ids, dst_ids, block_size=bs)
    specs = (jax.ShapeDtypeStruct((n_blocks, heads, bs, hd), "float32"),
             jax.ShapeDtypeStruct((3,), "int32"),
             jax.ShapeDtypeStruct((3,), "int32"))
    args = (rng.standard_normal((n_blocks, heads, bs, hd))
            .astype(np.float32),
            np.array([0, 2, 5], np.int32),
            np.array([6, 3, 1], np.int32))
    return fn, specs, args


_GRAPHS = {
    "map": _map_graph,
    "fused": _fused_graph,
    "gemm": _gemm_graph,
    "spmv_csr": lambda rng: _spmv_graph(rng, ell=False),
    "spmv_ell": lambda rng: _spmv_graph(rng, ell=True),
    "paged_copy": _paged_copy_graph,
}

_CASES = [(g, b) for g in sorted(_GRAPHS) for b in _BACKENDS]


@pytest.fixture(scope="session")
def build_dir(tmp_path_factory):
    return tmp_path_factory.mktemp("native_diff")


# ---------------------------------------------------------------------------
# the oracle: same inputs through jax and through the built .so
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph_name,backend", _CASES)
def test_native_matches_jax(build_dir, graph_name, backend):
    fn, specs, args = _GRAPHS[graph_name](_rng(graph_name))
    mod = pipeline.compile(fn, *specs,
                           options=CompileOptions(target=backend),
                           name=graph_name)
    nat = native.load_native(mod, build_dir / f"{graph_name}_{backend}")
    want = np.asarray(mod(*args))
    got = nat(*args)
    assert got.shape == want.shape and got.dtype == want.dtype
    np.testing.assert_allclose(got, want, atol=TOL, rtol=TOL)


def test_native_module_is_reentrant(build_dir):
    """Calling a loaded unit repeatedly is safe: lapis_setup guards
    Kokkos::initialize and lapis_initialize guards the weight upload, so
    the second call reuses live state instead of re-running either."""
    fn, specs, args = _GRAPHS["fused"](_rng("fused"))
    mod = pipeline.compile(fn, *specs,
                           options=CompileOptions(target="loops"),
                           name="reentry")
    nat = native.load_native(mod, build_dir / "reentry")
    first = nat(*args)
    for _ in range(3):
        np.testing.assert_array_equal(nat(*args), first)


def test_native_module_validates_inputs(build_dir):
    """The descriptor is enforced at the ctypes boundary — wrong arity
    and wrong shape fail loudly in Python, never segfault in C++."""
    fn, specs, args = _GRAPHS["gemm"](_rng("gemm"))
    mod = pipeline.compile(fn, *specs,
                           options=CompileOptions(target="loops"),
                           name="validate")
    nat = native.load_native(mod, build_dir / "validate")
    with pytest.raises(TypeError, match="1 arrays"):
        nat(args[0], args[0])
    with pytest.raises(TypeError, match="expected shape"):
        nat(args[0].T)


def test_descriptor_reports_graph_signature(build_dir):
    """The loaded descriptor round-trips the compiled graph's signature
    (shapes + dtype codes) — the contract native.py and any non-Python
    embedder rely on."""
    fn, specs, args = _GRAPHS["spmv_ell"](_rng("spmv_ell"))
    mod = pipeline.compile(fn, *specs,
                           options=CompileOptions(target="loops"),
                           name="desc")
    nat = native.load_native(mod, build_dir / "desc")
    assert [s for s, _ in nat.input_specs] == [s.shape for s in specs]
    assert [d for _, d in nat.input_specs] == \
        [np.dtype(s.dtype) for s in specs]
    assert nat.output_spec[0] == np.asarray(mod(*args)).shape


# ---------------------------------------------------------------------------
# the goldens are programs: build + run every pinned unit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("golden",
                         sorted(p.stem for p in GOLDEN_DIR.glob("*.cpp")))
def test_golden_unit_builds_and_runs(build_dir, golden):
    exe = native.build_exe(GOLDEN_DIR / f"{golden}.cpp",
                           build_dir / "goldens")
    proc = subprocess.run([str(exe)], capture_output=True, text=True,
                          timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "checksum:" in proc.stdout


def test_golden_set_covers_every_backend():
    """Adding a backend must add its golden units: the pinned set is
    (graphs x registered backends), nothing missing, nothing stale."""
    stems = {p.stem for p in GOLDEN_DIR.glob("*.cpp")}
    graphs = {"matmul", "fused_mlp", "spmv", "paged_swap"}
    want = {f"{g}_{b}" for g in graphs for b in _BACKENDS}
    assert stems == want

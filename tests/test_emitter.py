"""Emitter tests: executable path and freestanding Python source."""
import importlib.util
import sys

import jax
import numpy as np
import pytest

from repro.core import ops, pipeline
from repro.core.options import CompileOptions


def _mlp(rng):
    w1 = rng.standard_normal((16, 32), dtype=np.float32)
    w2 = rng.standard_normal((32, 4), dtype=np.float32)

    def fn(x):
        return ops.softmax(ops.matmul(ops.relu(ops.matmul(x, ops.constant(
            w1))), ops.constant(w2)))

    def ref(x):
        h = np.maximum(x @ w1, 0)
        z = h @ w2
        e = np.exp(z - z.max(axis=-1, keepdims=True))
        return e / e.sum(axis=-1, keepdims=True)

    return fn, ref


def test_executable_matches_reference(rng):
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(fn, x)
    np.testing.assert_allclose(np.asarray(mod(x)), ref(x), rtol=1e-4,
                               atol=1e-5)


def test_emitted_source_is_freestanding(tmp_path, rng):
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(fn, x,
                           options=CompileOptions(fuse_elementwise=False))
    path = tmp_path / "gen.py"
    mod.save_source(str(path))
    src = path.read_text()
    assert "lapis_initialize" in src          # paper §4.4
    assert "_WEIGHTS_B64" in src              # embedded weights
    assert "import repro" not in src          # freestanding
    spec = importlib.util.spec_from_file_location("gen_mod", path)
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)
    np.testing.assert_allclose(np.asarray(gen.fn(x)), ref(x), rtol=1e-4,
                               atol=1e-5)


def test_scalar_constants_inlined_as_literals(tmp_path, rng):
    def fn(x):
        return ops.mul(x, ops.constant(np.float32(2.5)))

    x = rng.standard_normal((4, 4), dtype=np.float32)
    mod = pipeline.compile(fn, x,
                           options=CompileOptions(fuse_elementwise=False))
    src = mod.emit_source()
    assert "2.5" in src                       # paper: literal inlining


def test_pallas_target_executable(rng):
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(
        fn, x, options=CompileOptions(target="pallas", interpret=True,
                                      prefer_library=False,
                                      fuse_elementwise=False))
    names = [op.opname for op in mod.graph.ops]
    assert "kokkos.team_parallel" in names
    np.testing.assert_allclose(np.asarray(mod(x)), ref(x), rtol=1e-4,
                               atol=1e-4)


def test_transfer_counting_lazy_weights(rng):
    from repro.core.dualview import TRANSFERS, reset_transfer_stats
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(fn, x)
    reset_transfer_stats()
    mod(x)
    first = TRANSFERS["h2d"]
    mod(x)
    assert TRANSFERS["h2d"] == first          # no re-uploads on 2nd call

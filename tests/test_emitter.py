"""Emitter tests: executable path and freestanding Python source."""
import importlib.util
import sys

import jax
import numpy as np
import pytest

from repro.core import ops, pipeline
from repro.core.options import CompileOptions


def _mlp(rng):
    w1 = rng.standard_normal((16, 32), dtype=np.float32)
    w2 = rng.standard_normal((32, 4), dtype=np.float32)

    def fn(x):
        return ops.softmax(ops.matmul(ops.relu(ops.matmul(x, ops.constant(
            w1))), ops.constant(w2)))

    def ref(x):
        h = np.maximum(x @ w1, 0)
        z = h @ w2
        e = np.exp(z - z.max(axis=-1, keepdims=True))
        return e / e.sum(axis=-1, keepdims=True)

    return fn, ref


def test_executable_matches_reference(rng):
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(fn, x)
    np.testing.assert_allclose(np.asarray(mod(x)), ref(x), rtol=1e-4,
                               atol=1e-5)


def test_emitted_source_is_freestanding(tmp_path, rng):
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(fn, x,
                           options=CompileOptions(fuse_elementwise=False))
    path = tmp_path / "gen.py"
    mod.save_source(str(path))
    src = path.read_text()
    assert "lapis_initialize" in src          # paper §4.4
    assert "_WEIGHTS_B64" in src              # embedded weights
    assert "import repro" not in src          # freestanding
    spec = importlib.util.spec_from_file_location("gen_mod", path)
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)
    np.testing.assert_allclose(np.asarray(gen.fn(x)), ref(x), rtol=1e-4,
                               atol=1e-5)


def test_scalar_constants_inlined_as_literals(tmp_path, rng):
    def fn(x):
        return ops.mul(x, ops.constant(np.float32(2.5)))

    x = rng.standard_normal((4, 4), dtype=np.float32)
    mod = pipeline.compile(fn, x,
                           options=CompileOptions(fuse_elementwise=False))
    src = mod.emit_source()
    assert "2.5" in src                       # paper: literal inlining


def test_pallas_target_executable(rng):
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(
        fn, x, options=CompileOptions(target="pallas", interpret=True,
                                      prefer_library=False,
                                      fuse_elementwise=False))
    names = [op.opname for op in mod.graph.ops]
    assert "kokkos.team_parallel" in names
    np.testing.assert_allclose(np.asarray(mod(x)), ref(x), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# fused graphs: the source path is total (kokkos.fused regions re-emit)
# ---------------------------------------------------------------------------

def _backends():
    from repro.core import backend as backend_mod
    return backend_mod.available_backends()


def _fused_mlp(rng):
    """MLP with bias→activation chains — fuse_elementwise folds each
    add→gelu / add→relu pair into a kokkos.fused region."""
    w1 = rng.standard_normal((16, 32), dtype=np.float32) * 0.3
    b1 = rng.standard_normal((8, 32), dtype=np.float32)
    w2 = rng.standard_normal((32, 4), dtype=np.float32) * 0.3
    b2 = rng.standard_normal((8, 4), dtype=np.float32)

    def fn(x):
        h = ops.gelu(ops.add(ops.matmul(x, ops.constant(w1)),
                             ops.constant(b1)))
        return ops.relu(ops.add(ops.matmul(h, ops.constant(w2)),
                                ops.constant(b2)))
    return fn


def _resnet_block(rng):
    """Small residual block: conv→bn→relu→conv→bn→(+x)→relu; the final
    add→relu chain fuses."""
    C = 4
    c1 = (rng.standard_normal((C, C, 3, 3)) * 0.1).astype(np.float32)
    c2 = (rng.standard_normal((C, C, 3, 3)) * 0.1).astype(np.float32)
    s = np.abs(rng.standard_normal((2, C))).astype(np.float32) + 0.5
    b = rng.standard_normal((2, C)).astype(np.float32)
    m = rng.standard_normal((2, C)).astype(np.float32)
    v = np.abs(rng.standard_normal((2, C))).astype(np.float32) + 0.5

    def fn(x):
        h = ops.relu(ops.batch_norm_inference(
            ops.conv2d(x, ops.constant(c1)), ops.constant(s[0]),
            ops.constant(b[0]), ops.constant(m[0]), ops.constant(v[0])))
        h = ops.batch_norm_inference(
            ops.conv2d(h, ops.constant(c2)), ops.constant(s[1]),
            ops.constant(b[1]), ops.constant(m[1]), ops.constant(v[1]))
        return ops.relu(ops.add(h, x))
    return fn


@pytest.mark.parametrize("graph", ["mlp", "resnet-block"])
def test_fused_source_round_trip_all_backends(tmp_path, rng, graph):
    """Acceptance: emit_python_source succeeds on fused graphs and the
    emitted module matches the compiled callable to 1e-5 on every
    registered backend."""
    if graph == "mlp":
        fn = _fused_mlp(rng)
        x = rng.standard_normal((8, 16), dtype=np.float32)
    else:
        fn = _resnet_block(rng)
        x = rng.standard_normal((2, 4, 8, 8), dtype=np.float32)
    for i, target in enumerate(_backends()):
        mod = pipeline.compile(
            fn, x, options=CompileOptions(target=target,
                                          fuse_elementwise=True))
        assert any(op.opname == "kokkos.fused" or
                   op.attrs.get("src") == "kokkos.fused"
                   for op in mod.graph.ops), target
        compiled = np.asarray(mod(x))
        path = tmp_path / f"gen_{graph.replace('-', '_')}_{i}.py"
        mod.save_source(str(path))          # must not raise — path is total
        src = path.read_text()
        assert "import repro" not in src    # still freestanding
        spec = importlib.util.spec_from_file_location(f"gen{i}", path)
        gen = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(gen)
        np.testing.assert_allclose(np.asarray(gen.fn(x)), compiled,
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"target={target}")


def test_fused_chain_is_one_launch(rng):
    """Acceptance: a fused chain of N elementwise ops executes as ONE
    mapped nest/kernel — launch_count drops by N-1 vs unfused."""
    def chain(x):
        return ops.relu(ops.sigmoid(ops.tanh(ops.exp(ops.neg(x)))))

    x = rng.standard_normal((32, 64), dtype=np.float32)
    for target in ("loops", "pallas", "xla"):
        fused = pipeline.compile(chain, x, options=CompileOptions(
            target=target, fuse_elementwise=True))
        unfused = pipeline.compile(chain, x, options=CompileOptions(
            target=target, fuse_elementwise=False))
        assert fused.launch_count == 1, target
        assert unfused.launch_count == 5, target
        assert fused.graph.pipeline_stats["fuse_elementwise"] == 4
        np.testing.assert_allclose(np.asarray(fused(x)),
                                   np.asarray(unfused(x)),
                                   rtol=1e-5, atol=1e-6)


def test_fused_region_in_print_ir_after_all_dump(rng):
    """--print-ir-after-all shows the structured fused body, not a blob."""
    from repro.core import passes, tracer
    from repro.core.options import use_options
    from repro.core.passmgr import PassManager
    g = tracer.trace(_fused_mlp(rng),
                     jax.ShapeDtypeStruct((8, 16), "float32"))
    dumped = []
    pm = PassManager(None, verify="full", print_ir_after_all=True,
                     sink=dumped.append)
    with use_options(CompileOptions(target="loops")) as o:
        pm.run(g, o)
    dump = "\n".join(dumped)
    assert "IR after fuse_elementwise" in dump
    assert "kokkos.fused" in dump
    # the body is inspectable: sub-ops and the yield are printed
    assert "linalg.gelu" in dump and "yield" in dump


def test_transfer_counting_lazy_weights(rng):
    from repro.core.dualview import TRANSFERS, reset_transfer_stats
    fn, ref = _mlp(rng)
    x = rng.standard_normal((8, 16), dtype=np.float32)
    mod = pipeline.compile(fn, x)
    reset_transfer_stats()
    mod(x)
    first = TRANSFERS["h2d"]
    mod(x)
    assert TRANSFERS["h2d"] == first          # no re-uploads on 2nd call
